"""Same-container old-vs-new A/B perf gate on the serving smoke workload.

Absolute smoke qps has moved ~2x between CI containers (PR 3, PR 5 both
had to be hand re-verified), so this gate never thresholds an absolute
number: it checks out the baseline ref into a temporary git worktree,
runs ``benchmarks.serving_bench --smoke`` for both trees back-to-back in
*this* container, and fails only when ``new_qps / old_qps`` drops below
the ratio threshold. Each side runs ``AB_RUNS`` times and keeps its best
qps (first-run jitter from the shared JIT cache is real).

The run appends ``{commit, qps_ratio, host_frac}`` to the ``ab_history``
list in BENCH_serving.json so the normalized trajectory is versioned
alongside the absolute headline numbers. When the new tree consumed a
tuning-cache record, a second new-tree measurement with
``REPRO_TUNING_DISABLE=1`` adds ``tuned_ratio`` (tuned / built-in-default
qps, same container) to the record — the autotuner's standing evidence.

When the gate *would* fail while the baseline disagrees with itself by
more than 2x across its own runs (best/worst self-ratio — a noisy
container, not a regression), the measurement is retried once before
failing and the history entry records ``retried: true``.

Environment knobs:

* ``AB_BASE_REF``  — baseline git ref (default ``HEAD~1``)
* ``AB_MIN_RATIO`` — failure threshold on new/old qps (default ``0.85``)
* ``AB_RUNS``      — smoke runs per side, best-of (default ``2``)
* ``AB_SKIP=1``    — skip the gate entirely
* ``AB_SCALE_MIN_RATIO`` — failure threshold on the scale leg's
  hierarchical/dense qps ratio at 8K vertices (default ``0.9``)
* ``AB_SCALE_SKIP=1`` — skip only the scale leg
* ``AB_SERVER_MIN_RATIO`` — failure threshold on the serving tier's
  through-the-wire/in-process goodput ratio (default ``0.6``)
* ``AB_SERVER_SKIP=1`` — skip only the server-overhead leg

Besides the old-vs-new smoke ratio, the gate runs a *same-tree* scale
leg: one 8K-vertex power-law graph served under both adjacency layouts
(``serving_bench --scale-gate``). The hierarchical layout must hold at
least ``AB_SCALE_MIN_RATIO`` of the dense layout's qps at a size where
both fit — the HBM-paged kernel buys footprint, and this pins how much
throughput it is allowed to cost.

A second same-tree leg pins the network serving tier's overhead
(DESIGN.md §10): ``load_bench --smoke --launch --rate 0`` drives the
real server process over HTTP with a closed-loop burst on the smoke
shapes, and the through-the-wire goodput must hold at least
``AB_SERVER_MIN_RATIO`` of the server's *own* in-process baseline
(the warm full-batch qps it measures at the end of warmup and
announces on its READY line — same engine instance, same compiled
programs, same queries, so the ratio isolates the wire, not container
noise). HTTP + JSON + tenant admission may tax throughput, and this
bounds the tax. The ratio lands in the same ``ab_history`` record as
``server_overhead``.

The gate skips gracefully (exit 0, with a message) when the baseline ref
does not resolve (shallow clone, first commit) or its bench fails to
run — a missing baseline must not block CI, only a measured regression.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import tempfile

ROOT = pathlib.Path(__file__).resolve().parent.parent
BENCH = ROOT / "BENCH_serving.json"


def _git(*args: str) -> subprocess.CompletedProcess:
    return subprocess.run(["git", *args], cwd=ROOT, capture_output=True,
                          text=True)


def _smoke_qps(tree: pathlib.Path, runs: int,
               extra_env: dict | None = None) -> tuple[float, float, dict]:
    """Best- and worst-of-``runs`` smoke qps for one source tree (plus
    the payload of the best run). The best/worst spread is the
    *self-ratio* — the gate's noise signal for this container."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(tree / "src")
    env.update(extra_env or {})
    best, worst, best_payload = 0.0, float("inf"), None
    for _ in range(runs):
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.serving_bench", "--smoke"],
            cwd=tree, env=env, capture_output=True, text=True,
            timeout=1800)
        if out.returncode != 0:
            raise RuntimeError(
                f"smoke bench failed in {tree}:\n{out.stderr[-2000:]}")
        payload = json.loads(out.stdout)
        qps = payload["queries_per_sec"]
        worst = min(worst, qps)
        if qps >= best:
            best, best_payload = qps, payload
    return best, worst, best_payload


def _scale_gate() -> int:
    """Same-tree hier-vs-dense qps ratio at 8K vertices (both layouts
    fit there, so the ratio isolates the kernel-variant cost)."""
    if os.environ.get("AB_SCALE_SKIP") == "1":
        print("ab_gate: scale leg skipped (AB_SCALE_SKIP=1)")
        return 0
    min_ratio = float(os.environ.get("AB_SCALE_MIN_RATIO", "0.9"))
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.serving_bench",
         "--scale-gate"],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        print("ab_gate: scale leg FAIL — bench errored:\n"
              f"{out.stderr[-2000:]}", file=sys.stderr)
        return 1
    entry = json.loads(out.stdout)["sizes"][0]
    ratio = entry["hier_dense_qps_ratio"]
    if entry["embeddings_identical"] is not True:
        print("ab_gate: scale leg FAIL — hier embeddings differ from "
              "the dense oracle at |V|="
              f"{entry['n_vertices']}", file=sys.stderr)
        return 1
    print(f"ab_gate: scale leg |V|={entry['n_vertices']} "
          f"hier={entry['legs']['hier-hbm']['queries_per_sec']:.1f} qps "
          f"vs dense={entry['legs']['dense-vmem']['queries_per_sec']:.1f}"
          f" qps, ratio={ratio:.3f} (threshold {min_ratio})")
    if ratio < min_ratio:
        print(f"ab_gate: scale leg FAIL — hier/dense qps ratio "
              f"{ratio:.3f} < {min_ratio}", file=sys.stderr)
        return 1
    return 0


def _server_overhead() -> tuple[float | None, int]:
    """Same-tree wire-vs-in-process goodput ratio: the serving tier's
    end-to-end tax (HTTP parse, NDJSON streaming, tenant admission,
    engine-thread handoff) measured as a closed burst through the real
    server process. The denominator is the server's *own* in-process
    baseline batch (same engine instance, same compiled programs, same
    query set — measured during warmup and announced on the READY
    line), so the ratio isolates the wire, not container noise.
    Returns (ratio, exit_code)."""
    if os.environ.get("AB_SERVER_SKIP") == "1":
        print("ab_gate: server leg skipped (AB_SERVER_SKIP=1)")
        return None, 0
    min_ratio = float(os.environ.get("AB_SERVER_MIN_RATIO", "0.6"))
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.load_bench", "--smoke",
         "--launch", "--rate", "0", "--n-requests", "32",
         "--repeats", "3"],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=1800)
    if out.returncode != 0:
        print("ab_gate: server leg FAIL — load_bench errored:\n"
              f"{out.stderr[-2000:]}", file=sys.stderr)
        return None, 1
    payload = json.loads(out.stdout)
    if payload["errors"]:
        print(f"ab_gate: server leg FAIL — {payload['errors']} wire "
              "requests errored", file=sys.stderr)
        return None, 1
    ratio = payload.get("server_overhead")
    if ratio is None:
        print("ab_gate: server leg FAIL — payload carries no "
              "server_overhead (no in-process baseline on the READY "
              "line?)", file=sys.stderr)
        return None, 1
    print(f"ab_gate: server leg wire={payload['goodput_qps']:.1f} qps "
          f"vs in-process={payload['inprocess_qps']:.1f} qps, "
          f"server_overhead={ratio:.3f} (threshold {min_ratio})")
    if ratio < min_ratio:
        print(f"ab_gate: server leg FAIL — wire/in-process goodput "
              f"ratio {ratio:.3f} < {min_ratio}", file=sys.stderr)
        return ratio, 1
    return ratio, 0


def main() -> int:
    if os.environ.get("AB_SKIP") == "1":
        print("ab_gate: skipped (AB_SKIP=1)")
        return 0
    base_ref = os.environ.get("AB_BASE_REF", "HEAD~1")
    min_ratio = float(os.environ.get("AB_MIN_RATIO", "0.85"))
    runs = int(os.environ.get("AB_RUNS", "2"))

    rev = _git("rev-parse", "--verify", f"{base_ref}^{{commit}}")
    if rev.returncode != 0:
        print(f"ab_gate: skipped (baseline ref {base_ref!r} does not "
              "resolve)")
        return 0
    base_commit = rev.stdout.strip()

    with tempfile.TemporaryDirectory(prefix="ab_gate_") as td:
        base_tree = pathlib.Path(td) / "base"
        add = _git("worktree", "add", "--detach", str(base_tree),
                   base_commit)
        if add.returncode != 0:
            print("ab_gate: skipped (worktree add failed: "
                  f"{add.stderr.strip()})")
            return 0
        retried = False
        try:
            try:
                old_qps, old_worst, _ = _smoke_qps(base_tree, runs)
            except (RuntimeError, json.JSONDecodeError,
                    subprocess.TimeoutExpired) as e:
                print(f"ab_gate: skipped (baseline bench unusable: {e})")
                return 0
            new_qps, _, new_payload = _smoke_qps(ROOT, runs)
            ratio = new_qps / max(old_qps, 1e-9)
            # noisy-container guard: when the gate would fail while the
            # baseline disagrees with *itself* by > 2x across its own
            # runs, the measurement — not the code — is suspect.
            # Re-measure both sides once before failing.
            self_ratio = old_qps / max(old_worst, 1e-9)
            if ratio < min_ratio and self_ratio > 2.0:
                print(f"ab_gate: retrying — baseline self-ratio "
                      f"{self_ratio:.2f} > 2.0 (noisy container), "
                      f"first ratio was {ratio:.3f}")
                retried = True
                try:
                    old_qps, old_worst, _ = _smoke_qps(base_tree, runs)
                except (RuntimeError, json.JSONDecodeError,
                        subprocess.TimeoutExpired) as e:
                    print("ab_gate: skipped (baseline bench unusable "
                          f"on retry: {e})")
                    return 0
                new_qps, _, new_payload = _smoke_qps(ROOT, runs)
                ratio = new_qps / max(old_qps, 1e-9)
        finally:
            _git("worktree", "remove", "--force", str(base_tree))

    # tuned-vs-default leg (DESIGN.md §9): re-run the *new* tree with
    # the tuning cache disabled so the scheduler falls back to the
    # built-in defaults, and record tuned/default qps in the same
    # container. Only meaningful when the tuned run actually consumed a
    # cache record; a builtin-resolved run would measure noise vs noise.
    tuned_ratio = None
    if new_payload.get("tuning", {}).get("source") == "tuning-cache":
        try:
            default_qps, _, _ = _smoke_qps(
                ROOT, runs, extra_env={"REPRO_TUNING_DISABLE": "1"})
            tuned_ratio = new_qps / max(default_qps, 1e-9)
            print(f"ab_gate: tuned={new_qps:.1f} qps vs "
                  f"default={default_qps:.1f} qps "
                  f"(tuned_ratio={tuned_ratio:.3f})")
        except (RuntimeError, json.JSONDecodeError,
                subprocess.TimeoutExpired) as e:
            print(f"ab_gate: tuned-vs-default leg skipped ({e})")

    # serving-tier overhead leg (DESIGN.md §10): measured before the
    # record is written so the wire/in-process ratio is versioned in
    # ab_history even when it fails the gate below
    server_ratio, server_rc = _server_overhead()

    head = _git("rev-parse", "--short", "HEAD").stdout.strip()
    record = {"commit": head, "qps_ratio": round(ratio, 4),
              "host_frac": round(new_payload.get("host_frac", 0.0), 4)}
    if tuned_ratio is not None:
        record["tuned_ratio"] = round(tuned_ratio, 4)
    if server_ratio is not None:
        record["server_overhead"] = round(server_ratio, 4)
    if retried:
        record["retried"] = True
    if BENCH.exists():
        bench = json.loads(BENCH.read_text())
        bench.setdefault("ab_history", []).append(record)
        BENCH.write_text(json.dumps(bench, indent=2) + "\n")

    print(f"ab_gate: old={old_qps:.1f} qps ({base_commit[:8]}), "
          f"new={new_qps:.1f} qps, ratio={ratio:.3f} "
          f"(threshold {min_ratio}), "
          f"host_frac={record['host_frac']:.3f}")
    if ratio < min_ratio:
        print(f"ab_gate: FAIL — qps ratio {ratio:.3f} < {min_ratio}",
              file=sys.stderr)
        return 1
    if server_rc:
        return server_rc
    return _scale_gate()


if __name__ == "__main__":
    sys.exit(main())
