#!/usr/bin/env bash
# Tier-1 verification in one invocation (see ROADMAP.md):
#
#     scripts/ci.sh               # full tier-1 suite + serving smoke run
#     scripts/ci.sh tests/test_serving.py -q   # pass-through args
#                                              # (skips the smoke run)
#
# Optional dependencies (hypothesis, networkx) are skipped gracefully by
# the suite when absent — see requirements.txt.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [ "$#" -gt 0 ]; then
    exec python -m pytest -x -q "$@"
fi
python -m pytest -x -q
# distributed suite re-run on its own (kept explicit so a marker or
# selection change in the main invocation can never silently drop the
# shard-as-segments / elastic-restore coverage)
python -m pytest tests/test_distributed.py -q
# autotuner smoke sweep (DESIGN.md §9): seconds-scale candidate sweep at
# the smoke shape on the jnp backend. Refreshes TUNING_CACHE.json so the
# serving smoke below consumes a schema-current record (check_smoke.py
# asserts the payload names it) and aborts if any candidate's embedding
# digest deviates — tuning may move time, never results.
python -m repro.tuning.autotune --smoke > /dev/null
# tiny-size serving benchmark smoke run: exercises the megastep + async
# pipeline, the request/handle streaming API, the distributed
# shard-as-segments workload, and the repeated-template pattern-cache
# workload end to end (does not touch the committed BENCH_serving.json).
# check_smoke.py asserts the payload — the QueryResult.to_dict schema,
# the streaming workload (streamed union == blocking rows, TTFE
# strictly < completion latency on the uniform workload), the
# pattern-store/cache metrics, and that warm-started queries out-prune
# cold ones — and prints a one-line summary.
python -m benchmarks.serving_bench --smoke | python scripts/check_smoke.py
# scale smoke (DESIGN.md §2): tiny graph-size sweep under both
# adjacency layouts — every size must enumerate bit-identical embedding
# sets across the dense whole-VMEM and hierarchical HBM-paged variants,
# and the payload must name the kernel variant each leg ran.
python -m benchmarks.serving_bench --smoke --scale | python scripts/check_smoke.py --scale
# chaos smoke (DESIGN.md §8): the same workload under a seeded
# FaultPlan — every query must end in a terminal status (never hang),
# the injected digest corruption must be caught by the validator, and
# at least one query must recover through the host fallback.
python -m benchmarks.serving_bench --smoke --chaos | python scripts/check_smoke.py --chaos
# network serving tier smoke (DESIGN.md §10): load_bench --launch owns
# the whole server lifecycle — spawn `python -m repro.server.launch` on
# a free port, wait for the READY line, drive an open-loop Poisson
# request stream over HTTP through two tenants, then SIGTERM (graceful
# drain) and reap, teardown running even when the bench fails.
# check_smoke --server asserts every request ended in a terminal typed
# status over the wire, zero unexplained errors, >= 1 streamed chunk
# strictly before completion for every row-producing query, and that
# /slo exported the live gauges.
python -m benchmarks.load_bench --smoke --launch | python scripts/check_smoke.py --server
# normalized old-vs-new A/B perf gate: both trees benched back-to-back
# in this container, only the qps *ratio* is thresholded (absolute
# smoke qps has moved ~2x between containers). Appends a
# {commit, qps_ratio, host_frac} record to BENCH_serving.json; skips
# gracefully when the baseline ref is unavailable. AB_SKIP=1 to skip,
# AB_BASE_REF / AB_MIN_RATIO / AB_RUNS to tune.
python scripts/ab_gate.py
