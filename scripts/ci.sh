#!/usr/bin/env bash
# Tier-1 verification in one invocation (see ROADMAP.md):
#
#     scripts/ci.sh               # run the full tier-1 suite
#     scripts/ci.sh tests/test_serving.py -q   # pass-through args
#
# Optional dependencies (hypothesis, networkx) are skipped gracefully by
# the suite when absent — see requirements.txt.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
if [ "$#" -gt 0 ]; then
    exec python -m pytest -x -q "$@"
fi
exec python -m pytest -x -q
