"""Assert the serving-bench smoke payload's shape and print a one-line
summary (wired into scripts/ci.sh — the smoke run used to be piped to
/dev/null, which let metric regressions ship silently).

Reads the JSON payload from stdin, checks the expected top-level keys
(including the pattern-store / pattern-cache metrics), and checks the
repeated-template workload actually demonstrates the warm-start win
(warm prune rate above cold).
"""
import json
import sys

REQUIRED = [
    "n_queries", "queries_per_sec", "total_embeddings", "p50_ms", "p99_ms",
    "waves", "mean_wave_occupancy", "steady_wave_occupancy", "prune_rate",
    "megastep_depth", "dispatch_time_s", "device_sync_time_s",
    "host_time_s",
    # bounded hashed Δ store + cross-query template cache
    "pattern_capacity", "store_evictions", "store_overwrites",
    "store_load_factor", "pattern_cache",
    "trap_workload", "distributed_workload", "repeated_template_workload",
]
REQUIRED_TEMPLATE = [
    "n_bait", "n_repeats", "cold_prune_rate", "warm_prune_rate",
    "cold_rows", "warm_rows_per_query", "warm_started", "cache",
]


def main() -> int:
    payload = json.load(sys.stdin)
    missing = [k for k in REQUIRED if k not in payload]
    if missing:
        print(f"smoke payload missing keys: {missing}", file=sys.stderr)
        return 1
    rt = payload["repeated_template_workload"]
    missing = [k for k in REQUIRED_TEMPLATE if k not in rt]
    if missing:
        print(f"repeated_template_workload missing keys: {missing}",
              file=sys.stderr)
        return 1
    if not rt["warm_prune_rate"] > rt["cold_prune_rate"]:
        print("warm-start regression: warm prune rate "
              f"{rt['warm_prune_rate']:.3f} <= cold "
              f"{rt['cold_prune_rate']:.3f}", file=sys.stderr)
        return 1
    if rt["warm_started"] < rt["n_repeats"]:
        print(f"warm_started={rt['warm_started']} < "
              f"n_repeats={rt['n_repeats']}: template cache not hitting",
              file=sys.stderr)
        return 1
    print("serving_bench --smoke: OK "
          f"(qps={payload['queries_per_sec']:.1f}, "
          f"prune_rate={payload['prune_rate']:.2f}, "
          f"warm_prune={rt['warm_prune_rate']:.2f} vs "
          f"cold={rt['cold_prune_rate']:.2f}, "
          f"warm_started={rt['warm_started']}, "
          f"evictions={payload['store_evictions']})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
