"""Assert the serving-bench smoke payload's shape and print a one-line
summary (wired into scripts/ci.sh — the smoke run used to be piped to
/dev/null, which let metric regressions ship silently).

Reads the JSON payload from stdin and checks:

* the expected top-level keys (pattern-store / pattern-cache metrics,
  TTFE percentiles);
* the per-query ``results`` entries — ``QueryResult.to_dict()``
  payloads consumed by schema (typed status, builtin scalars), not by
  ad-hoc key picking;
* the streaming workload: the streamed union equals the blocking API's
  rows, and TTFE is *strictly* below full-completion latency on the
  uniform workload (embeddings really are delivered mid-flight);
* the repeated-template workload actually demonstrates the warm-start
  win (warm prune rate above cold).

``--chaos`` validates the ``serving_bench --chaos`` recovery payload
instead (DESIGN.md §8): every query ended in a terminal status (never a
hang), the injected digest corruption was caught by the validator —
never silently absorbed — and at least one query recovered via the
host fallback.

``--scale`` validates the ``serving_bench --scale`` payload
(BENCH_scale.json, DESIGN.md §2): every size entry names its kernel
variant per leg, both-layout sizes enumerated bit-identical embedding
sets, and past-the-ceiling sizes ran hierarchical-only with a peak
device footprint under 10% of the dense-equivalent adjacency block.

``--server`` validates the ``load_bench --smoke`` payload from the
network serving tier (DESIGN.md §10): every request reached a terminal
status over the wire, zero unexplained errors, at least one streamed
chunk arrived strictly before completion for every row-producing query
(TTFE < latency — the wire genuinely streams), and the server's /slo
endpoint exported the live gauges (queue_depth, resident_queries,
backpressure_absorbed).
"""
import argparse
import json
import pathlib
import sys

TUNING_CACHE = pathlib.Path(__file__).resolve().parent.parent / \
    "TUNING_CACHE.json"

REQUIRED = [
    "n_queries", "queries_per_sec", "total_embeddings", "p50_ms", "p99_ms",
    "waves", "mean_wave_occupancy", "steady_wave_occupancy", "prune_rate",
    "megastep_depth", "dispatch_time_s", "device_sync_time_s",
    "host_time_s",
    # disjoint host-time breakdown + device-resident stack flag
    # (ISSUE 6: the <20%-of-wall criterion is measured from the payload)
    "host_frac", "host_admission_time_s", "host_digest_time_s",
    "host_retirement_time_s", "host_flush_time_s", "device_stacks",
    # streaming serving API (DESIGN.md §4)
    "ttfe_p50_ms", "ttfe_p99_ms", "results", "streaming",
    # bounded hashed Δ store + cross-query template cache
    "pattern_capacity", "store_evictions", "store_overwrites",
    "store_load_factor", "pattern_cache",
    # autotuning (DESIGN.md §9): the payload must name the tuning
    # record the server resolved
    "tuning",
    # live-load gauges + absorbed-backpressure tally from slo_report
    # (the serving tier's /slo endpoint re-exports these)
    "queue_depth", "resident_queries", "backpressure_absorbed",
    "trap_workload", "distributed_workload", "repeated_template_workload",
]
REQUIRED_TEMPLATE = [
    "n_bait", "n_repeats", "cold_prune_rate", "warm_prune_rate",
    "cold_rows", "warm_rows_per_query", "warm_started", "cache",
]
# QueryResult.to_dict() schema: key -> allowed types (None allowed for
# ttfe_ms — a query that found nothing has no first embedding)
RESULT_SCHEMA = {
    "query_id": (int,), "status": (str,), "n_found": (int,),
    "recursions": (int,), "latency_ms": (float,),
    "ttfe_ms": (float, type(None)), "timed_out": (bool,),
    "aborted": (bool,),
}
STATUSES = ("ok", "limit", "timeout", "cancelled", "error", "shed")
CHAOS_REQUIRED = [
    "n_queries", "statuses", "all_terminal", "faults_planned",
    "faults_fired", "fired", "fault_counters", "digest_failures_caught",
    "recovered_queries", "recovery_p50_ms", "recovery_p99_ms",
]
SCALE_VARIANTS = ("hier-hbm", "dense-vmem")
SCALE_LEG_REQUIRED = [
    "adjacency_variant", "adjacency_bytes", "chunk_words", "wall_time_s",
    "queries_per_sec", "prune_rate", "total_embeddings",
    "peak_device_bytes",
]
SCALE_ENTRY_REQUIRED = [
    "n_vertices", "n_edges", "n_queries", "query_size",
    "dense_equiv_adjacency_bytes", "legs", "embeddings_identical",
    "hier_dense_qps_ratio",
]
# hierarchical peak footprint must stay under this fraction of the
# dense-equivalent adjacency block at past-the-ceiling sizes
SCALE_PEAK_FRAC_MAX = 0.1
SERVER_REQUIRED = [
    "open_loop", "target_rate_qps", "n_requests", "wall_time_s",
    "goodput_qps", "statuses", "shed", "errors", "p50_ms", "p99_ms",
    "ttfe_p50_ms", "ttfe_p99_ms", "total_rows", "per_tenant",
    "fairness_jain", "queries", "server", "server_slo",
]
# the satellite gauges must survive the wire to /slo
SERVER_SLO_GAUGES = ("queue_depth", "resident_queries",
                     "backpressure_absorbed")


def _check_tuning(payload) -> str | None:
    """The payload must name the resolved tuning record, and a
    committed TUNING_CACHE.json must match the cache schema *and*
    actually be the record the bench consumed (DESIGN.md §9)."""
    t = payload.get("tuning")
    if not isinstance(t, dict):
        return "tuning must be the resolved-record descriptor dict"
    for k in ("source", "record", "params", "schema_hash"):
        if k not in t:
            return f"tuning descriptor missing {k!r}"
    if t["source"] not in ("tuning-cache", "builtin"):
        return f"tuning source {t['source']!r} unknown"
    if not isinstance(t["params"], dict) or not t["params"]:
        return "tuning params must be the resolved knob dict"
    if not TUNING_CACHE.exists():
        return None
    try:
        cache = json.loads(TUNING_CACHE.read_text())
    except (OSError, json.JSONDecodeError) as e:
        return f"TUNING_CACHE.json unreadable: {e}"
    if not isinstance(cache.get("version"), int):
        return "TUNING_CACHE.json missing integer 'version'"
    if not isinstance(cache.get("schema_hash"), str):
        return "TUNING_CACHE.json missing 'schema_hash'"
    records = cache.get("records")
    if not isinstance(records, dict) or not records:
        return "TUNING_CACHE.json 'records' must be a non-empty dict"
    for name, rec in records.items():
        for k in ("name", "schema_hash", "params", "measured"):
            if k not in rec:
                return f"record {name!r} missing {k!r}"
        if not isinstance(rec["params"], dict):
            return f"record {name!r} params must be a dict"
    # a fresh-schema cache exists, so the smoke server (same backend /
    # device / shape the tuner measured) must have consumed a record
    if cache["schema_hash"] == t["schema_hash"] \
            and t["source"] != "tuning-cache":
        return ("TUNING_CACHE.json is present and schema-current but "
                "the bench resolved builtin defaults — the record was "
                "not consumed")
    if t["source"] == "tuning-cache" and t["record"] not in records:
        return (f"payload names tuning record {t['record']!r} which is "
                "not in TUNING_CACHE.json")
    return None


def _check_result_dicts(results) -> str | None:
    if not isinstance(results, list) or not results:
        return "results must be a non-empty list of QueryResult dicts"
    for r in results:
        for key, types in RESULT_SCHEMA.items():
            if key not in r:
                return f"result {r.get('query_id')}: missing {key!r}"
            if not isinstance(r[key], types):
                return (f"result {r.get('query_id')}: {key}="
                        f"{r[key]!r} is not JSON-safe {types}")
        if r["status"] not in STATUSES:
            return f"result {r.get('query_id')}: bad status {r['status']!r}"
        if r["timed_out"] != (r["status"] == "timeout"):
            return (f"result {r.get('query_id')}: timed_out inconsistent "
                    f"with status {r['status']!r}")
    return None


def check_chaos(payload) -> int:
    missing = [k for k in CHAOS_REQUIRED if k not in payload]
    if missing:
        print(f"chaos payload missing keys: {missing}", file=sys.stderr)
        return 1
    if not payload["all_terminal"]:
        print("chaos regression: a query ended outside the terminal "
              f"statuses (got {payload['statuses']}) — something hung "
              "or leaked", file=sys.stderr)
        return 1
    bad = [s for s in payload["statuses"] if s not in STATUSES]
    if bad:
        print(f"chaos payload has unknown statuses: {bad}",
              file=sys.stderr)
        return 1
    if payload["faults_fired"] < 3:
        print("chaos regression: only "
              f"{payload['faults_fired']}/{payload['faults_planned']} "
              "planned faults fired — the schedule no longer reaches "
              "its boundary crossings", file=sys.stderr)
        return 1
    if payload["digest_failures_caught"] < 1:
        print("chaos regression: the injected digest corruption was "
              "NOT caught by the validator (silently absorbed)",
              file=sys.stderr)
        return 1
    if payload["recovered_queries"] < 1:
        print("chaos regression: no query recovered via the host "
              "fallback", file=sys.stderr)
        return 1
    p50 = payload["recovery_p50_ms"]
    p99 = payload["recovery_p99_ms"]
    print("serving_bench --chaos: OK "
          f"(n={payload['n_queries']}, statuses={payload['statuses']}, "
          f"faults_fired={payload['faults_fired']}/"
          f"{payload['faults_planned']}, "
          f"digest_caught={payload['digest_failures_caught']}, "
          f"recovered={payload['recovered_queries']}, "
          f"recovery_p50={p50:.0f}ms, recovery_p99={p99:.0f}ms)")
    return 0


def check_scale(payload) -> int:
    for k in ("smoke", "backend", "sizes"):
        if k not in payload:
            print(f"scale payload missing {k!r}", file=sys.stderr)
            return 1
    sizes = payload["sizes"]
    if not isinstance(sizes, list) or not sizes:
        print("scale payload 'sizes' must be a non-empty list",
              file=sys.stderr)
        return 1
    summary = []
    for entry in sizes:
        n = entry.get("n_vertices")
        missing = [k for k in SCALE_ENTRY_REQUIRED if k not in entry]
        if missing:
            print(f"scale |V|={n}: missing keys {missing}",
                  file=sys.stderr)
            return 1
        legs = entry["legs"]
        if not isinstance(legs, dict) or "hier-hbm" not in legs:
            print(f"scale |V|={n}: legs must include the hier-hbm "
                  "variant", file=sys.stderr)
            return 1
        for name, leg in legs.items():
            missing = [k for k in SCALE_LEG_REQUIRED if k not in leg]
            if missing:
                print(f"scale |V|={n} leg {name!r}: missing {missing}",
                      file=sys.stderr)
                return 1
            # the payload must *name* the kernel variant the leg ran,
            # and the name must agree with the leg key
            if leg["adjacency_variant"] not in SCALE_VARIANTS \
                    or leg["adjacency_variant"] != name:
                print(f"scale |V|={n} leg {name!r}: adjacency_variant="
                      f"{leg['adjacency_variant']!r} unknown or "
                      "inconsistent", file=sys.stderr)
                return 1
        if "dense-vmem" in legs:
            # both layouts ran — the hierarchical leg must be the
            # bit-identical oracle match
            if entry["embeddings_identical"] is not True:
                print(f"scale |V|={n}: hier embeddings differ from the "
                      "dense oracle (embeddings_identical="
                      f"{entry['embeddings_identical']!r})",
                      file=sys.stderr)
                return 1
        else:
            # past-the-VMEM-ceiling size: the whole point — peak device
            # footprint well under the dense-equivalent block
            frac = entry.get("peak_frac_of_dense")
            if not isinstance(frac, float) \
                    or not frac < SCALE_PEAK_FRAC_MAX:
                print(f"scale |V|={n}: peak_frac_of_dense={frac!r} "
                      f"!< {SCALE_PEAK_FRAC_MAX} — the hierarchical "
                      "layout is not beating the dense footprint",
                      file=sys.stderr)
                return 1
        hier = legs["hier-hbm"]
        summary.append(
            f"|V|={n}:{'/'.join(sorted(legs))} "
            f"qps={hier['queries_per_sec']:.1f} "
            f"peak={hier['peak_device_bytes'] / 2**20:.1f}MiB")
    print("serving_bench --scale: OK "
          f"(backend={payload['backend']}, {'; '.join(summary)})")
    return 0


def check_server(payload) -> int:
    missing = [k for k in SERVER_REQUIRED if k not in payload]
    if missing:
        print(f"server payload missing keys: {missing}", file=sys.stderr)
        return 1
    queries = payload["queries"]
    if not isinstance(queries, list) or not queries:
        print("server payload 'queries' must be a non-empty list",
              file=sys.stderr)
        return 1
    streamed_before_done = 0
    for r in queries:
        if r.get("status") not in STATUSES:
            print(f"server request {r.get('i')}: non-terminal or "
                  f"unknown status {r.get('status')!r} — a wire "
                  "request hung or died untyped", file=sys.stderr)
            return 1
        if r.get("n_rows", 0) > 0:
            # the streaming SLO, measured through the wire: every
            # row-producing query must have seen >= 1 chunk strictly
            # before its terminal event
            if r.get("n_chunks", 0) < 1 or r.get("ttfe_ms") is None \
                    or not r["ttfe_ms"] < r["latency_ms"]:
                print(f"server request {r.get('i')}: rows="
                      f"{r['n_rows']} but chunks={r.get('n_chunks')} "
                      f"ttfe={r.get('ttfe_ms')} !< latency="
                      f"{r.get('latency_ms')} — the wire is not "
                      "streaming mid-flight", file=sys.stderr)
                return 1
            streamed_before_done += 1
    if streamed_before_done < 1:
        print("server smoke produced zero row-producing queries — the "
              "streaming assertion is vacuous", file=sys.stderr)
        return 1
    if payload["errors"] != 0:
        bad = [r for r in queries if r["status"] == "error"]
        print(f"server smoke: {payload['errors']} unexplained errors, "
              f"e.g. {bad[0].get('error')!r}", file=sys.stderr)
        return 1
    if len(payload["per_tenant"]) < 2:
        print("server smoke ran fewer than 2 tenants — multi-tenant "
              "admission untested", file=sys.stderr)
        return 1
    fair = payload["fairness_jain"]
    if not isinstance(fair, float) or not (0.0 < fair <= 1.0 + 1e-9):
        print(f"server smoke: fairness_jain={fair!r} out of (0, 1]",
              file=sys.stderr)
        return 1
    slo = payload["server_slo"]
    for k in SERVER_SLO_GAUGES:
        rep = slo.get("report", slo)
        if not isinstance(rep.get(k), int) or rep[k] < 0:
            print(f"server /slo missing live gauge {k!r} "
                  f"(got {rep.get(k)!r})", file=sys.stderr)
            return 1
    print("load_bench --smoke: OK "
          f"(n={payload['n_requests']}, "
          f"rate={payload['target_rate_qps']:g}qps, "
          f"goodput={payload['goodput_qps']:.1f}qps, "
          f"statuses={payload['statuses']}, "
          f"streamed_before_done={streamed_before_done}, "
          f"ttfe_p50={payload['ttfe_p50_ms']:.0f}ms vs "
          f"p50={payload['p50_ms']:.0f}ms, "
          f"tenants={sorted(payload['per_tenant'])}, "
          f"fairness={fair:.3f})")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--chaos", action="store_true",
                      help="validate the --chaos recovery payload instead")
    mode.add_argument("--scale", action="store_true",
                      help="validate the --scale sweep payload instead")
    mode.add_argument("--server", action="store_true",
                      help="validate the load_bench --smoke serving-tier "
                           "payload instead")
    args = ap.parse_args()
    payload = json.load(sys.stdin)
    if args.chaos:
        return check_chaos(payload)
    if args.scale:
        return check_scale(payload)
    if args.server:
        return check_server(payload)
    missing = [k for k in REQUIRED if k not in payload]
    if missing:
        print(f"smoke payload missing keys: {missing}", file=sys.stderr)
        return 1
    err = _check_result_dicts(payload["results"])
    if err:
        print(f"results payload invalid: {err}", file=sys.stderr)
        return 1
    err = _check_tuning(payload)
    if err:
        print(f"tuning payload invalid: {err}", file=sys.stderr)
        return 1
    # per-workload store load factors (the capacity right-sizing
    # evidence): every workload leg must report how full its Δ store got
    for leg in ("trap_workload", "repeated_template_workload"):
        lf = payload[leg].get("store_load_factor")
        if not isinstance(lf, float) or not (0.0 <= lf <= 1.0):
            print(f"{leg}: store_load_factor={lf!r} missing or out of "
                  "[0, 1]", file=sys.stderr)
            return 1
    # streaming assertions: union pinned to the blocking API, and TTFE
    # strictly below completion latency (uniform workload) — i.e. the
    # stream genuinely yields before the query retires
    st = payload["streaming"]
    if not st.get("stream_equals_batch"):
        print("streaming regression: streamed union != blocking "
              "embedding set", file=sys.stderr)
        return 1
    if st["ttfe_p50_ms"] is None \
            or not st["ttfe_p50_ms"] < st["completion_p50_ms"]:
        print("streaming regression: ttfe_p50 "
              f"{st['ttfe_p50_ms']} !< completion_p50 "
              f"{st['completion_p50_ms']}", file=sys.stderr)
        return 1
    if payload["ttfe_p50_ms"] is None \
            or not payload["ttfe_p50_ms"] < payload["p50_ms"]:
        print("streaming regression: uniform ttfe_p50 "
              f"{payload['ttfe_p50_ms']} !< p50 {payload['p50_ms']}",
              file=sys.stderr)
        return 1
    rt = payload["repeated_template_workload"]
    missing = [k for k in REQUIRED_TEMPLATE if k not in rt]
    if missing:
        print(f"repeated_template_workload missing keys: {missing}",
              file=sys.stderr)
        return 1
    if not rt["warm_prune_rate"] > rt["cold_prune_rate"]:
        print("warm-start regression: warm prune rate "
              f"{rt['warm_prune_rate']:.3f} <= cold "
              f"{rt['cold_prune_rate']:.3f}", file=sys.stderr)
        return 1
    if rt["warm_started"] < rt["n_repeats"]:
        print(f"warm_started={rt['warm_started']} < "
              f"n_repeats={rt['n_repeats']}: template cache not hitting",
              file=sys.stderr)
        return 1
    tun = payload["tuning"]
    print("serving_bench --smoke: OK "
          f"(qps={payload['queries_per_sec']:.1f}, "
          f"tuning={tun['record'] or tun['source']}, "
          f"prune_rate={payload['prune_rate']:.2f}, "
          f"ttfe_p50={payload['ttfe_p50_ms']:.0f}ms vs "
          f"p50={payload['p50_ms']:.0f}ms, "
          f"stream_equals_batch={st['stream_equals_batch']}, "
          f"warm_prune={rt['warm_prune_rate']:.2f} vs "
          f"cold={rt['cold_prune_rate']:.2f}, "
          f"warm_started={rt['warm_started']}, "
          f"evictions={payload['store_evictions']})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
