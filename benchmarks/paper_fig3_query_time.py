"""Paper Fig. 3 analogue: average query processing time, our method vs
re-implemented baselines (QuickSI-style, GraphQL-style, naive Ullmann).

The paper's comparison structure: per query-size sets, average seconds
per query, DNF if over budget. Baselines share our graph substrate:
  * naive      — Algorithm 1, label+degree filter only (Ullmann-like),
  * quicksi    — Algorithm 1 + rarity matching order (QuickSI-style),
  * graphql    — Algorithm 1 + NLF local filters (GraphQL-style),
  * ours       — Algorithm 2 (dead-end pruning) + full filtering
                 (the paper's method on top of CFL-style pruning).
"""
from __future__ import annotations

import time

from repro.core.backtrack import backtrack_deadend, backtrack_naive
from repro.core.candidates import build_candidates
from repro.core.ordering import connected_min_candidate_order, rarity_order
from repro.data.graph_gen import query_set, trap_graph, yeast_like_graph

BUDGET_PER_QUERY_S = 2.0


def _variant(name, query, data):
    if name == "naive":
        cand = build_candidates(query, data, use_nlf=False, use_cfl=False)
        order = connected_min_candidate_order(query, cand)
        return backtrack_naive(query, data, cand=cand, order=order,
                               limit=1000, time_budget_s=BUDGET_PER_QUERY_S)
    if name == "quicksi":
        cand = build_candidates(query, data, use_nlf=False, use_cfl=False)
        order = rarity_order(query, data)
        return backtrack_naive(query, data, cand=cand, order=order,
                               limit=1000, time_budget_s=BUDGET_PER_QUERY_S)
    if name == "graphql":
        cand = build_candidates(query, data, use_nlf=True, use_cfl=False)
        order = connected_min_candidate_order(query, cand)
        return backtrack_naive(query, data, cand=cand, order=order,
                               limit=1000, time_budget_s=BUDGET_PER_QUERY_S)
    if name == "ours":
        return backtrack_deadend(query, data, limit=1000,
                                 time_budget_s=BUDGET_PER_QUERY_S)
    raise ValueError(name)


def run(csv_rows: list, budget_s: float = 90.0) -> None:
    t0 = time.time()
    data = yeast_like_graph(0)
    for nq in (8, 12, 16, 20):
        queries = query_set(data, nq, 5, seed=1000 + nq)
        for variant in ("naive", "quicksi", "graphql", "ours"):
            if time.time() - t0 > budget_s:
                return
            total, found, dnf = 0.0, 0, 0
            for q in queries:
                r = _variant(variant, q, data)
                total += r.stats.wall_time_s
                found += r.stats.found
                dnf += int(r.stats.aborted and r.stats.found < 1000)
            csv_rows.append((f"fig3_yeastlike_q{nq}_{variant}",
                             total * 1e6 / len(queries),
                             f"found={found};dnf={dnf}"))
    # the trap family shows the asymptotic separation cleanly
    q, g = trap_graph(n_b=150, n_c=150, n_good=2, tail_len=2, seed=0)
    for variant in ("quicksi", "graphql", "ours"):
        r = _variant(variant, q, g)
        csv_rows.append((f"fig3_trap150_{variant}",
                         r.stats.wall_time_s * 1e6,
                         f"recursions={r.stats.recursions};"
                         f"found={r.stats.found}"))
