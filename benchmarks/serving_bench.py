"""Multi-query serving throughput benchmark -> BENCH_serving.json.

Drives the paper's evaluation protocol as a serving workload: a large
mixed batch of random-walk queries against one data graph, all executed
concurrently through the shared-wave scheduler (continuous batching,
DESIGN.md §4). Tracks the serving-perf trajectory across PRs:

    queries/sec, mean + steady-state wave occupancy, prune rate,
    p50/p99 latency, TTFE (time-to-first-embedding) p50/p99, timeouts,
    host-vs-device time split, and the megastep depth the run used (so
    trajectories stay comparable when the fusion depth changes between
    PRs). Per-query results ride along as ``QueryResult.to_dict()``
    payloads. A streaming workload consumes the same uniform queries
    through ``MatchHandle.stream()`` (DESIGN.md §4) and pins the
    streamed union to the blocking API's rows with TTFE strictly below
    completion latency. A distributed workload (shard-as-segments,
    DESIGN.md §3) additionally records qps and prune rate vs shard
    count on the trap query, and a repeated-template workload
    (DESIGN.md §6) records the cold vs warm-started prune rate on the
    corridor graph — the cross-query pattern-cache win.

    PYTHONPATH=src python -m benchmarks.serving_bench
    PYTHONPATH=src python -m benchmarks.serving_bench --smoke   # CI
    PYTHONPATH=src python -m benchmarks.serving_bench --smoke --chaos
    PYTHONPATH=src python -m benchmarks.run --only serving

``--chaos`` replays the uniform workload under a seeded FaultPlan
(dispatch exceptions/hangs, digest corruption, retry exhaustion, a
flush drop and an admission failure — DESIGN.md §8) and emits a
recovery payload instead: every query must end in a terminal status
(ok/limit/timeout/error — never hang), the injected digest corruption
must be caught by the validator, and the payload reports the
recovered-query count plus recovery-latency p50/p99.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

N_QUERIES = 96
QUERY_SIZE = 6
N_SLOTS = 64
WAVE_SIZE = 256
KPR = 8
LIMIT = 1000
TIME_BUDGET_S = 10.0

# graph-size sweep (--scale): 512 fits the dense kernel comfortably,
# 8K sits just under the HBM threshold (both layouts run and must agree
# bit-for-bit), 64K is past the VMEM ceiling — the dense [V, W] block
# alone would be 512 MB, so only the hierarchical layout runs there
SCALE_SIZES = (512, 8192, 65536)

_OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serving.json"
_OUT_SCALE = pathlib.Path(__file__).resolve().parent.parent / "BENCH_scale.json"


def run(csv_rows: list | None = None, budget_s: float = 90.0,
        n_queries: int = N_QUERIES, out_path: pathlib.Path | None = _OUT,
        smoke: bool = False) -> dict:
    """``smoke=True`` shrinks every dimension to a seconds-scale CI run
    and leaves the committed BENCH_serving.json untouched."""
    from repro.data.graph_gen import ba_labeled_graph, query_set
    from repro.serving.query_server import QueryServer

    if smoke:
        n_queries, query_size = 8, 4
        n_slots, wave_size, kpr = 8, 64, 8
        n_vertices, extra_edges = 128, 128
        out_path = None
    else:
        query_size = QUERY_SIZE
        n_slots, wave_size, kpr = N_SLOTS, WAVE_SIZE, KPR
        n_vertices, extra_edges = 512, 512

    data = ba_labeled_graph(n_vertices, 3, 24, extra_edges=extra_edges,
                            seed=0)
    queries = query_set(data, query_size, n_queries, seed=7)

    def make_server(graph, **kw):
        return QueryServer(graph, backend="engine",
                           time_budget_s=TIME_BUDGET_S,
                           wave_size=wave_size, kpr=kpr, n_slots=n_slots,
                           **kw)

    # warm-up on a throwaway server with identical shapes: the jitted
    # wave programs are module-level, so the compile cost lands here and
    # neither the timed run nor the reported SLO stats include it. The
    # full batch is replayed so the warm-up reaches every program the
    # timed run will dispatch (the adaptive scheduler only switches to
    # the fused megastep after a few low-prune waves).
    make_server(data, limit=LIMIT).submit_batch(queries)
    server = make_server(data, limit=LIMIT)
    t0 = time.perf_counter()
    results = server.submit_batch(queries)
    wall = time.perf_counter() - t0

    rep = server.slo_report()
    payload = {
        "data_graph": {"n_vertices": data.n, "n_edges": data.n_edges,
                       "n_labels": data.n_labels},
        "n_queries": len(results),
        "query_size": query_size,
        "n_slots": n_slots,
        "wave_size": wave_size,
        "kpr": kpr,
        "limit": LIMIT,
        "megastep_depth": rep["megastep_depth"],
        "wall_time_s": wall,
        "queries_per_sec": len(results) / wall,
        "total_embeddings": int(sum(r.n_found for r in results)),
        "timeouts": int(sum(r.timed_out for r in results)),
        "p50_ms": rep["p50_ms"],
        "p99_ms": rep["p99_ms"],
        # live-load gauges + absorbed-backpressure tally (zero queued /
        # resident after a drained batch; the serving tier's /slo
        # endpoint exports the same keys mid-flight)
        "queue_depth": rep["queue_depth"],
        "resident_queries": rep["resident_queries"],
        "backpressure_absorbed": rep["backpressure_absorbed"],
        # streaming SLO: time to first embedding (recorded per query by
        # the scheduler's incremental delivery, DESIGN.md §4) — always
        # strictly below the completion latency on this workload
        "ttfe_p50_ms": rep.get("ttfe_p50_ms"),
        "ttfe_p99_ms": rep.get("ttfe_p99_ms"),
        "waves": rep["waves"],
        "mean_wave_occupancy": rep["mean_occupancy"],
        "steady_wave_occupancy": rep["steady_occupancy"],
        "steady_waves": rep["steady_waves"],
        "peak_concurrent_queries": rep["peak_active"],
        "deadend_prunes": rep["deadend_prunes"],
        "rows_created": rep["rows_created"],
        "prune_rate": rep["prune_rate"],
        # host-vs-device split: dispatch = packing + async dispatch,
        # device_sync = blocked materializing digests, host = digest
        # processing. Their sum < wall because the double-buffered
        # pipeline overlaps host work with in-flight device waves.
        "dispatch_time_s": rep["dispatch_time_s"],
        "device_sync_time_s": rep["device_sync_time_s"],
        "host_time_s": rep["host_time_s"],
        "host_frac": rep["host_time_s"] / wall,
        # disjoint host-time breakdown (where the host wall actually
        # goes now that frontier stacks are device-resident): digest
        # folding, admission, retirement, Δ pattern flushing
        "host_admission_time_s": rep["host_admission_time_s"],
        "host_digest_time_s": rep["host_digest_time_s"],
        "host_retirement_time_s": rep["host_retirement_time_s"],
        "host_flush_time_s": rep["host_flush_time_s"],
        "device_stacks": rep["device_stacks"],
        # bounded hashed Δ store (patterns.store): O(capacity) resident
        # memory, eviction only ever loses pruning
        "pattern_capacity": rep["pattern_capacity"],
        "store_evictions": rep["store_evictions"],
        "store_overwrites": rep["store_overwrites"],
        "store_load_factor": rep["store_load_factor"],
        "pattern_cache": rep["pattern_cache"],
        # the tuning record the server resolved at construction
        # (DESIGN.md §9): names the consumed TUNING_CACHE.json record
        # ("source" = "tuning-cache") or the built-in defaults
        "tuning": rep["tuning"],
        # per-query JSON-safe summaries (QueryResult.to_dict) — what a
        # serving client would log; check_smoke.py validates the schema
        "results": [r.to_dict() for r in results],
    }

    # --- streaming workload: the same uniform queries consumed through
    # MatchHandle.stream() — the streamed union must equal the blocking
    # API's rows, and the first batch must land strictly before
    # completion (TTFE < latency).
    import numpy as np
    sserver = make_server(data, limit=LIMIT)
    handles = [sserver.submit_async(q, query_id=i)
               for i, q in enumerate(queries)]
    n_batches = 0
    stream_rows: dict[int, set] = {}
    for i, h in enumerate(handles):
        rows = set()
        for batch in h.stream():
            rows.update(map(tuple, batch.tolist()))
            n_batches += 1
        stream_rows[i] = rows
    sresults = [h.result() for h in handles]
    srep = sserver.slo_report()
    equal = all(
        stream_rows[i] == {tuple(np.asarray(e).tolist())
                           for e in r.embeddings}
        for i, r in enumerate(sresults))
    payload["streaming"] = {
        "n_queries": len(sresults),
        "n_batches": n_batches,
        "stream_equals_batch": bool(equal),
        "ttfe_p50_ms": srep.get("ttfe_p50_ms"),
        "ttfe_p99_ms": srep.get("ttfe_p99_ms"),
        "completion_p50_ms": srep["p50_ms"],
        "completion_p99_ms": srep["p99_ms"],
    }

    # --- trap workload: clients hammering the paper's Fig. 1 hard
    # case — the regime where dead-end learning dominates, so the prune
    # rate is a meaningful trajectory metric (it is ~0 on uniform
    # random-walk traffic, matching the paper's easy-query ablations).
    from repro.data.graph_gen import trap_graph
    nb = 12 if smoke else 60
    n_trap = 4 if smoke else N_SLOTS
    tq, tg = trap_graph(n_b=nb, n_c=nb, n_good=2, tail_len=2, seed=0)
    make_server(tg, limit=None).submit_batch([tq])
    tserver = make_server(tg, limit=None)
    t0 = time.perf_counter()
    tres = tserver.submit_batch([tq] * n_trap)
    twall = time.perf_counter() - t0
    trep = tserver.slo_report()
    payload["trap_workload"] = {
        "n_queries": len(tres),
        "wall_time_s": twall,
        "queries_per_sec": len(tres) / twall,
        "total_embeddings": int(sum(r.n_found for r in tres)),
        "mean_wave_occupancy": trep["mean_occupancy"],
        "steady_wave_occupancy": trep["steady_occupancy"],
        "deadend_prunes": trep["deadend_prunes"],
        "rows_created": trep["rows_created"],
        "prune_rate": trep["prune_rate"],
        "device_sync_time_s": trep["device_sync_time_s"],
        "host_time_s": trep["host_time_s"],
        # per-workload store pressure (the capacity right-sizing signal:
        # uniform traffic holds ~15 patterns, trap/corridor are the
        # workloads that actually fill the store)
        "store_load_factor": trep["store_load_factor"],
        "pattern_capacity": trep["pattern_capacity"],
    }

    # --- distributed workload: one heavy trap query matched as
    # shard-as-segments (DESIGN.md §3) across increasing shard counts —
    # qps and prune rate vs n_shards track that full Δ sharing holds the
    # single-engine prune rate while shards add wave occupancy.
    from repro.core.distributed import DistributedMatcher
    dist_rows = []
    shard_counts = (1, 2) if smoke else (1, 2, 4, 8)
    dnb = 12 if smoke else 40
    dq, dg = trap_graph(n_b=dnb, n_c=dnb, n_good=2, tail_len=2, seed=0)
    for n_shards in shard_counts:
        dm = DistributedMatcher(dg, n_shards=n_shards,
                                wave_size=(32 if smoke else 64),
                                kpr=(4 if smoke else 8))
        dm.match(dq, limit=None)                     # warm-up
        dm = DistributedMatcher(dg, n_shards=n_shards,
                                wave_size=(32 if smoke else 64),
                                kpr=(4 if smoke else 8))
        t0 = time.perf_counter()
        dres = dm.match(dq, limit=None)
        dwall = time.perf_counter() - t0
        prunes = dres.stats.deadend_prunes
        rows = dres.stats.rows_created
        dist_rows.append({
            "n_shards": n_shards,
            "wall_time_s": dwall,
            "queries_per_sec": 1.0 / dwall if dwall > 0 else 0.0,
            "embeddings": dres.stats.found,
            "deadend_prunes": prunes,
            "rows_created": rows,
            "prune_rate": prunes / max(1, prunes + rows),
            "steals": dres.stats.steals,
        })
    payload["distributed_workload"] = dist_rows

    # --- repeated-template workload: the serving scenario the pattern
    # cache exists for — millions of users resubmitting the same query
    # template. The corridor graph's dead-ends are prefix-independent
    # (all μ == 0) and invisible to the candidate filters, so a cold run
    # can't prune at all (each bait is entered exactly once) while a
    # warm-started rerun prunes every bait at the first extraction.
    from repro.data.graph_gen import corridor_graph
    n_bait = 24 if smoke else 128
    n_rep = 3 if smoke else 24
    rq, rg = corridor_graph(n_bait=n_bait)
    make_server(rg, limit=None).submit_batch([rq])       # compile warm-up
    rserver = make_server(rg, limit=None)
    cold = rserver.submit_batch([rq])[0]                 # populates cache
    t0 = time.perf_counter()
    warm = rserver.submit_batch([rq] * n_rep)
    rwall = time.perf_counter() - t0
    rrep = rserver.slo_report()

    def rate(results):
        prunes = sum(r.stats.deadend_prunes for r in results)
        rows = sum(r.stats.rows_created for r in results)
        return prunes / max(1, prunes + rows)

    payload["repeated_template_workload"] = {
        "n_bait": n_bait,
        "n_repeats": n_rep,
        "wall_time_s": rwall,
        "queries_per_sec": n_rep / rwall if rwall > 0 else 0.0,
        "cold_prune_rate": rate([cold]),
        "warm_prune_rate": rate(warm),
        "cold_rows": cold.stats.rows_created,
        "warm_rows_per_query": (sum(r.stats.rows_created for r in warm)
                                / len(warm)),
        "warm_started": rrep["warm_started"],
        "cache": rrep["pattern_cache"],
        "store_load_factor": rrep["store_load_factor"],
        "pattern_capacity": rrep["pattern_capacity"],
    }

    if out_path is not None:
        # regeneration must not wipe the normalized A/B trajectory that
        # scripts/ab_gate.py versions alongside the absolute numbers
        if out_path.exists():
            try:
                prev = json.loads(out_path.read_text())
                if "ab_history" in prev:
                    payload["ab_history"] = prev["ab_history"]
            except (json.JSONDecodeError, OSError):
                pass
        out_path.write_text(json.dumps(payload, indent=2) + "\n")
    if csv_rows is not None:
        csv_rows.append((
            f"serving_q{query_size}x{len(results)}_s{n_slots}",
            wall * 1e6 / len(results),
            f"qps={payload['queries_per_sec']:.1f};"
            f"occ={payload['mean_wave_occupancy']:.2f};"
            f"steady_occ={payload['steady_wave_occupancy']:.2f};"
            f"prune_rate={payload['prune_rate']:.2f}"))
        s = payload["streaming"]
        ttfe50 = s["ttfe_p50_ms"]        # None when nothing was found
        csv_rows.append((
            f"streaming_q{query_size}x{s['n_queries']}",
            (ttfe50 or 0.0) * 1e3,
            (f"ttfe_p50={ttfe50:.0f}ms;" if ttfe50 is not None
             else "ttfe_p50=n/a;")
            + f"completion_p50={s['completion_p50_ms']:.0f}ms;"
            f"equal={s['stream_equals_batch']}"))
        t = payload["trap_workload"]
        csv_rows.append((
            f"serving_trap{nb}x{t['n_queries']}",
            t["wall_time_s"] * 1e6 / t["n_queries"],
            f"qps={t['queries_per_sec']:.1f};"
            f"occ={t['mean_wave_occupancy']:.2f};"
            f"prune_rate={t['prune_rate']:.2f}"))
        d = payload["distributed_workload"][-1]
        csv_rows.append((
            f"distributed_trap{dnb}_s{d['n_shards']}",
            d["wall_time_s"] * 1e6,
            f"qps={d['queries_per_sec']:.1f};"
            f"prune_rate={d['prune_rate']:.2f};"
            f"steals={d['steals']}"))
        rt = payload["repeated_template_workload"]
        csv_rows.append((
            f"template_corridor{n_bait}x{n_rep}",
            rt["wall_time_s"] * 1e6 / n_rep,
            f"qps={rt['queries_per_sec']:.1f};"
            f"cold_prune={rt['cold_prune_rate']:.2f};"
            f"warm_prune={rt['warm_prune_rate']:.2f};"
            f"warm_started={rt['warm_started']}"))
    return payload


def run_scale(smoke: bool = False,
              out_path: pathlib.Path | None = _OUT_SCALE,
              sizes: tuple[int, ...] | None = None,
              n_queries: int | None = None) -> dict:
    """Graph-size sweep for the hierarchical adjacency layout
    (DESIGN.md §2) -> BENCH_scale.json.

    For each |V| in ``SCALE_SIZES`` a labeled power-law graph
    (``data.graph_gen.powerlaw_graph``, degree-descending relabeled)
    serves a small uniform query batch through :class:`WaveScheduler`
    under both adjacency layouts where both fit: the dense whole-VMEM
    variant and the hierarchical HBM-paged variant. Records per leg:
    qps, prune rate, the resident adjacency bytes and the leg's peak
    device bytes (``jax.live_arrays()`` delta). At 512 and 8K the two
    legs must enumerate bit-identical embedding sets (refinement is
    bit-exact, so the whole schedule evolves identically); at 64K the
    dense leg is skipped — its adjacency block alone is 512 MB — and
    the payload instead pins the hierarchical peak as a fraction of the
    dense-equivalent block (``scripts/check_smoke.py --scale`` asserts
    < 10%).

    Capacities are deliberately small (wave 64 / 2 slots / stack 256):
    the sweep measures the *adjacency* scaling, so scheduler state must
    not dominate the footprint at 64K.
    """
    import gc

    import jax

    from repro.core.vectorized import WaveScheduler
    from repro.data.graph_gen import powerlaw_graph, query_set
    from repro.kernels.config import get_backend

    if sizes is None:
        sizes = (256, 1024) if smoke else SCALE_SIZES
    n_q = n_queries if n_queries is not None else (3 if smoke else 6)
    query_size = 5
    if smoke:
        out_path = None

    def live_bytes() -> int:
        gc.collect()
        return int(sum(int(getattr(x, "nbytes", 0))
                       for x in jax.live_arrays()))

    def leg(data, queries, hier: bool) -> tuple[dict, list]:
        base = live_bytes()
        kw = dict(n_slots=2, wave_size=64, kpr=4, stack_capacity=256,
                  limit=10_000, hier_adjacency=hier)

        def one():
            s = WaveScheduler(data, **kw)
            for q in queries:
                s.submit(q)
            s.run()
            return s

        one()                                 # compile warm-up
        gc.collect()
        t0 = time.perf_counter()
        s = one()
        wall = time.perf_counter() - t0
        peak = live_bytes() - base
        stats = s.scheduler_stats()
        embs = [sorted(map(tuple, s.finished[qid].embeddings))
                for qid in sorted(s.finished)]
        n_emb = sum(len(e) for e in embs)
        row = {
            "adjacency_variant": stats["adjacency_variant"],
            "adjacency_bytes": stats["adjacency_bytes"],
            "chunk_words": stats["chunk_words"],
            "wall_time_s": wall,
            "queries_per_sec": len(queries) / wall if wall > 0 else 0.0,
            "prune_rate": stats["prune_rate"],
            "total_embeddings": int(n_emb),
            "peak_device_bytes": int(peak),
        }
        del s
        gc.collect()
        return row, embs

    rows = []
    for n in sizes:
        data = powerlaw_graph(n, 3, 16, seed=0)
        queries = query_set(data, query_size, n_q, seed=7)
        w = (n + 31) // 32
        dense_equiv = n * w * 4
        entry = {
            "n_vertices": n,
            "n_edges": data.n_edges,
            "n_queries": n_q,
            "query_size": query_size,
            "dense_equiv_adjacency_bytes": dense_equiv,
            "legs": {},
        }
        hier_row, hier_embs = leg(data, queries, hier=True)
        entry["legs"]["hier-hbm"] = hier_row
        # past the VMEM ceiling the dense leg is the thing that cannot
        # exist — everything below 16K also runs it as the oracle
        if n < 16384:
            dense_row, dense_embs = leg(data, queries, hier=False)
            entry["legs"]["dense-vmem"] = dense_row
            entry["embeddings_identical"] = bool(hier_embs == dense_embs)
            entry["hier_dense_qps_ratio"] = (
                hier_row["queries_per_sec"]
                / max(dense_row["queries_per_sec"], 1e-9))
        else:
            entry["embeddings_identical"] = None
            entry["hier_dense_qps_ratio"] = None
            entry["peak_frac_of_dense"] = (
                hier_row["peak_device_bytes"] / dense_equiv)
        rows.append(entry)
        print(f"# scale |V|={n}: {json.dumps(entry['legs'])}",
              file=sys.stderr)

    payload = {
        "smoke": bool(smoke),
        "backend": get_backend(),
        "wave_size": 64, "n_slots": 2, "kpr": 4, "limit": 10_000,
        "sizes": rows,
    }
    if out_path is not None:
        out_path.write_text(json.dumps(payload, indent=2) + "\n")
    return payload


def run_chaos(smoke: bool = True) -> dict:
    """The uniform serving workload under a seeded :class:`FaultPlan`
    (DESIGN.md §8). Returns a recovery payload — validated by
    ``scripts/check_smoke.py --chaos`` — instead of the perf payload;
    never writes BENCH_serving.json."""
    import numpy as np
    from repro.core.faults import FaultPlan, FaultSpec
    from repro.data.graph_gen import ba_labeled_graph, query_set
    from repro.serving.query_server import QueryServer

    if smoke:
        n_queries, query_size = 8, 4
        n_slots, wave_size, kpr = 8, 64, 8
        n_vertices, extra_edges = 128, 128
    else:
        n_queries, query_size = 32, QUERY_SIZE
        n_slots, wave_size, kpr = N_SLOTS, WAVE_SIZE, KPR
        n_vertices, extra_edges = 512, 512

    data = ba_labeled_graph(n_vertices, 3, 24, extra_edges=extra_edges,
                            seed=0)
    queries = query_set(data, query_size, n_queries, seed=7)
    # the seeded chaos schedule: one of every failure mode the runtime
    # is expected to absorb, spread across the run's boundary crossings
    plan = FaultPlan([
        FaultSpec("dispatch", "exception", at=2),      # retried
        FaultSpec("digest", "corrupt", at=2),          # quarantined
        FaultSpec("dispatch", "hang", at=4),           # watchdog
        FaultSpec("dispatch", "exception", at=6, times=4),  # exhausted
        FaultSpec("flush", "exception", at=1),         # dropped batch
    ], seed=0)
    server = QueryServer(data, backend="engine",
                         time_budget_s=TIME_BUDGET_S, limit=LIMIT,
                         wave_size=wave_size, kpr=kpr, n_slots=n_slots,
                         faults=plan)
    t0 = time.perf_counter()
    results = server.submit_batch(queries)
    wall = time.perf_counter() - t0
    statuses = [r.status for r in results]
    terminal = ("ok", "limit", "timeout", "error", "cancelled", "shed")
    recovered = [r for r in results
                 if getattr(r.stats, "fallback", False)]
    rec_lat = np.asarray([r.latency_s for r in recovered])
    f = server.scheduler.scheduler_stats()["faults"]
    return {
        "chaos": True,
        "smoke": bool(smoke),
        "n_queries": len(results),
        "wall_time_s": wall,
        "statuses": {s: statuses.count(s) for s in sorted(set(statuses))},
        # the headline chaos invariant: every query reached a terminal
        # status — an injected fault may cost work, never a hang
        "all_terminal": all(s in terminal for s in statuses),
        "faults_planned": len(plan.specs),
        "faults_fired": len(plan.fired),
        "fired": [[site, kind, n] for site, kind, n, _ in plan.fired],
        "fault_counters": f,
        "digest_failures_caught": f["digest_failures"],
        "recovered_queries": len(recovered),
        "recovery_p50_ms": (float(np.percentile(rec_lat, 50) * 1e3)
                            if len(rec_lat) else None),
        "recovery_p99_ms": (float(np.percentile(rec_lat, 99) * 1e3)
                            if len(rec_lat) else None),
        "total_embeddings": int(sum(r.n_found for r in results)),
    }


if __name__ == "__main__":
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                           / "src"))
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-size CI run; does not write BENCH_serving")
    ap.add_argument("--chaos", action="store_true",
                    help="run the seeded fault-injection workload and "
                         "emit the recovery payload instead")
    ap.add_argument("--scale", action="store_true",
                    help="run the graph-size sweep (dense vs "
                         "hierarchical adjacency) and emit/write "
                         "BENCH_scale.json instead")
    ap.add_argument("--scale-gate", action="store_true",
                    help="single 8K-vertex hier-vs-dense leg for "
                         "scripts/ab_gate.py; never writes a file")
    args = ap.parse_args()
    if args.scale_gate:
        payload = run_scale(out_path=None, sizes=(8192,), n_queries=3)
        print(json.dumps(payload, indent=2))
        sys.exit(0)
    if args.chaos:
        print(json.dumps(run_chaos(smoke=args.smoke), indent=2))
        sys.exit(0)
    if args.scale:
        payload = run_scale(smoke=args.smoke)
        print(json.dumps(payload, indent=2))
        if not args.smoke:
            print(f"# wrote {_OUT_SCALE}", file=sys.stderr)
        sys.exit(0)
    payload = run(smoke=args.smoke)
    print(json.dumps(payload, indent=2))
    if not args.smoke:
        print(f"# wrote {_OUT}", file=sys.stderr)