"""Multi-query serving throughput benchmark -> BENCH_serving.json.

Drives the paper's evaluation protocol as a serving workload: a large
mixed batch of random-walk queries against one data graph, all executed
concurrently through the shared-wave scheduler (continuous batching,
DESIGN.md §4). Tracks the serving-perf trajectory across PRs:

    queries/sec, mean + steady-state wave occupancy, prune rate,
    p50/p99 latency, timeouts.

    PYTHONPATH=src python -m benchmarks.serving_bench
    PYTHONPATH=src python -m benchmarks.run --only serving
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

N_QUERIES = 96
QUERY_SIZE = 6
N_SLOTS = 64
WAVE_SIZE = 256
KPR = 8
LIMIT = 1000
TIME_BUDGET_S = 10.0

_OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serving.json"


def run(csv_rows: list | None = None, budget_s: float = 90.0,
        n_queries: int = N_QUERIES, out_path: pathlib.Path = _OUT) -> dict:
    from repro.data.graph_gen import ba_labeled_graph, query_set
    from repro.serving.query_server import QueryServer

    data = ba_labeled_graph(512, 3, 24, extra_edges=512, seed=0)
    queries = query_set(data, QUERY_SIZE, n_queries, seed=7)

    # warm-up on a throwaway server with identical shapes: the jitted
    # wave programs are module-level, so the compile cost lands here and
    # neither the timed run nor the reported SLO stats include it
    QueryServer(data, backend="engine", limit=LIMIT,
                time_budget_s=TIME_BUDGET_S, wave_size=WAVE_SIZE,
                kpr=KPR, n_slots=N_SLOTS).submit_batch(queries[:1])
    server = QueryServer(data, backend="engine", limit=LIMIT,
                         time_budget_s=TIME_BUDGET_S, wave_size=WAVE_SIZE,
                         kpr=KPR, n_slots=N_SLOTS)
    t0 = time.perf_counter()
    results = server.submit_batch(queries)
    wall = time.perf_counter() - t0

    rep = server.slo_report()
    payload = {
        "data_graph": {"n_vertices": data.n, "n_edges": data.n_edges,
                       "n_labels": data.n_labels},
        "n_queries": len(results),
        "query_size": QUERY_SIZE,
        "n_slots": N_SLOTS,
        "wave_size": WAVE_SIZE,
        "kpr": KPR,
        "limit": LIMIT,
        "wall_time_s": wall,
        "queries_per_sec": len(results) / wall,
        "total_embeddings": int(sum(r.n_found for r in results)),
        "timeouts": int(sum(r.timed_out for r in results)),
        "p50_ms": rep["p50_ms"],
        "p99_ms": rep["p99_ms"],
        "waves": rep["waves"],
        "mean_wave_occupancy": rep["mean_occupancy"],
        "steady_wave_occupancy": rep["steady_occupancy"],
        "steady_waves": rep["steady_waves"],
        "peak_concurrent_queries": rep["peak_active"],
        "deadend_prunes": rep["deadend_prunes"],
        "rows_created": rep["rows_created"],
        "prune_rate": rep["prune_rate"],
    }
    # --- trap workload: 64 clients hammering the paper's Fig. 1 hard
    # case — the regime where dead-end learning dominates, so the prune
    # rate is a meaningful trajectory metric (it is ~0 on uniform
    # random-walk traffic, matching the paper's easy-query ablations).
    from repro.data.graph_gen import trap_graph
    tq, tg = trap_graph(n_b=60, n_c=60, n_good=2, tail_len=2, seed=0)
    QueryServer(tg, backend="engine", limit=None,
                time_budget_s=TIME_BUDGET_S, wave_size=WAVE_SIZE,
                kpr=KPR, n_slots=N_SLOTS).submit_batch([tq])
    tserver = QueryServer(tg, backend="engine", limit=None,
                          time_budget_s=TIME_BUDGET_S, wave_size=WAVE_SIZE,
                          kpr=KPR, n_slots=N_SLOTS)
    t0 = time.perf_counter()
    tres = tserver.submit_batch([tq] * N_SLOTS)
    twall = time.perf_counter() - t0
    trep = tserver.slo_report()
    payload["trap_workload"] = {
        "n_queries": len(tres),
        "wall_time_s": twall,
        "queries_per_sec": len(tres) / twall,
        "total_embeddings": int(sum(r.n_found for r in tres)),
        "mean_wave_occupancy": trep["mean_occupancy"],
        "steady_wave_occupancy": trep["steady_occupancy"],
        "deadend_prunes": trep["deadend_prunes"],
        "rows_created": trep["rows_created"],
        "prune_rate": trep["prune_rate"],
    }

    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    if csv_rows is not None:
        csv_rows.append((
            f"serving_q{QUERY_SIZE}x{len(results)}_s{N_SLOTS}",
            wall * 1e6 / len(results),
            f"qps={payload['queries_per_sec']:.1f};"
            f"occ={payload['mean_wave_occupancy']:.2f};"
            f"steady_occ={payload['steady_wave_occupancy']:.2f};"
            f"prune_rate={payload['prune_rate']:.2f}"))
        t = payload["trap_workload"]
        csv_rows.append((
            f"serving_trap60x{t['n_queries']}",
            t["wall_time_s"] * 1e6 / t["n_queries"],
            f"qps={t['queries_per_sec']:.1f};"
            f"occ={t['mean_wave_occupancy']:.2f};"
            f"prune_rate={t['prune_rate']:.2f}"))
    return payload


if __name__ == "__main__":
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                           / "src"))
    payload = run()
    print(json.dumps(payload, indent=2))
    print(f"# wrote {_OUT}", file=sys.stderr)
