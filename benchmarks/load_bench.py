"""Open-loop load benchmark for the network serving tier -> the
``serving_tier`` section of BENCH_serving.json (DESIGN.md §10).

Unlike ``serving_bench`` (closed-loop: the next query waits for the
batch), this generator models real traffic: **Poisson arrivals at a
target rate**, each request fired at its scheduled instant whether or
not earlier ones completed — so queueing delay under overload shows up
in the latency tail instead of silently throttling the offered load
(no coordinated omission). Every request goes through the real server
process over HTTP, via :class:`repro.server.client.ServeClient`:

* latency is measured from the *scheduled arrival* (not the actual
  send) to the terminal event;
* TTFE is scheduled-arrival -> first streamed ``chunk`` event — the
  wire-level streaming SLO;
* goodput counts ``ok``/``limit`` completions per second of wall;
* traffic is spread across tenants (weighted round-robin), and
  per-tenant goodput yields a Jain fairness index normalized by the
  configured WFQ weights.

    python -m benchmarks.load_bench --smoke --launch          # CI leg
    python -m benchmarks.load_bench --launch                  # full:
        # rate ladder -> BENCH_serving.json["serving_tier"]
    python -m benchmarks.load_bench --host H --port P --rate 40
    python -m benchmarks.load_bench --smoke --launch --rate 0 # burst
        # (closed-loop worker pool; ab_gate.py's server_overhead leg)

``--launch`` owns the whole server lifecycle: spawn
``python -m repro.server.launch`` on a free port, wait for the READY
line, drive it, then SIGTERM (graceful drain) and reap — teardown runs
even when the bench fails.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import signal
import subprocess
import sys
import threading
import time

import numpy as np

ROOT = pathlib.Path(__file__).resolve().parent.parent
_OUT = ROOT / "BENCH_serving.json"

# smoke shapes mirror serving_bench --smoke exactly, so the ab_gate
# server_overhead leg compares like against like (same graph, same
# query distribution, same engine knobs — only the wire differs)
SMOKE_GRAPH = ["--graph", "ba", "--graph-n", "128",
               "--graph-extra-edges", "128", "--graph-labels", "24",
               "--graph-seed", "0"]
SMOKE_ENGINE = ["--n-slots", "8", "--wave-size", "64", "--kpr", "8",
                "--limit", "1000", "--time-budget-s", "10"]
FULL_GRAPH = ["--graph", "ba", "--graph-n", "512",
              "--graph-extra-edges", "512", "--graph-labels", "24",
              "--graph-seed", "0"]
# two-tenant mix: alpha carries 2x the weight and 2x the traffic, so
# under WFQ both should see ~equal per-weight goodput (fairness ~1.0)
TENANTS = {"alpha": {"weight": 2.0}, "beta": {"weight": 1.0}}
TENANT_MIX = ["alpha", "alpha", "beta"]


def _build_queries(n_vertices: int, extra_edges: int, query_size: int,
                   n: int, seed: int = 7) -> list:
    from repro.data.graph_gen import ba_labeled_graph, query_set
    data = ba_labeled_graph(n_vertices, 3, 24, extra_edges=extra_edges,
                            seed=0)
    return query_set(data, query_size, n, seed=seed)


# ----------------------------------------------------------------------
# server lifecycle (--launch)
# ----------------------------------------------------------------------
def launch_server(extra_args: list[str], timeout_s: float = 600.0
                  ) -> tuple[subprocess.Popen, dict]:
    """Spawn ``python -m repro.server.launch`` and wait for its READY
    line. Caller must :func:`stop_server` the returned process."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, "-m", "repro.server.launch", "--port", "0",
           "--tenants", json.dumps(TENANTS), *extra_args]
    proc = subprocess.Popen(cmd, cwd=ROOT, env=env,
                            stdout=subprocess.PIPE,
                            stderr=subprocess.DEVNULL, text=True)
    deadline = time.monotonic() + timeout_s
    while True:
        if proc.poll() is not None:
            raise RuntimeError(
                f"server exited early with code {proc.returncode}")
        if time.monotonic() > deadline:
            proc.kill()
            raise RuntimeError("server did not become ready in time")
        line = proc.stdout.readline()
        if line.startswith("REPRO_SERVER_READY "):
            return proc, json.loads(line.split(" ", 1)[1])


def stop_server(proc: subprocess.Popen, timeout_s: float = 60.0) -> int:
    """SIGTERM (graceful drain) then reap; SIGKILL past the timeout."""
    if proc.poll() is None:
        proc.send_signal(signal.SIGTERM)
        try:
            proc.wait(timeout=timeout_s)
        except subprocess.TimeoutExpired:
            proc.kill()
            proc.wait(timeout=10)
    if proc.stdout is not None:
        proc.stdout.close()
    return proc.returncode


# ----------------------------------------------------------------------
# the open-loop run
# ----------------------------------------------------------------------
def run_load(host: str, port: int, queries: list, *, rate: float,
             seed: int = 0, tenant_mix: list[str] | None = None,
             limit: int | None = None) -> dict:
    """Drive one open-loop run: ``len(queries)`` requests, Poisson
    arrivals at ``rate`` req/s. For ``rate <= 0`` this dispatches to
    :func:`run_burst` (closed-loop capacity probe — a bounded worker
    pool issuing back-to-back, used by the A/B overhead gate; one
    thread per request would measure client thread-spawn stagger, not
    server goodput)."""
    if rate <= 0:
        return run_burst(host, port, queries, tenant_mix=tenant_mix,
                         limit=limit)
    from repro.server.client import ServeClient, ServerError

    mix = tenant_mix or TENANT_MIX
    n = len(queries)
    rng = np.random.default_rng(seed)
    inter = rng.exponential(1.0 / rate, size=n)
    arrivals = np.cumsum(inter)
    client = ServeClient(host, port)
    records: list[dict] = [None] * n
    options = {} if limit is None else {"limit": limit}

    t0 = time.perf_counter()

    def worker(i: int) -> None:
        tenant = mix[i % len(mix)]
        t_sched = arrivals[i]
        delay = t0 + t_sched - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        t_send = time.perf_counter() - t0
        rec = {"i": i, "tenant": tenant, "t_sched_s": float(t_sched),
               "send_delay_ms": (t_send - t_sched) * 1e3,
               "n_chunks": 0, "n_rows": 0, "ttfe_ms": None,
               "status": None, "error": None}
        try:
            for ev in client.stream(queries[i % len(queries)],
                                    tenant=tenant, options=options,
                                    request_id=i):
                now = time.perf_counter() - t0
                if ev["event"] == "chunk" and ev["rows"]:
                    if rec["n_chunks"] == 0:
                        rec["ttfe_ms"] = (now - t_sched) * 1e3
                    rec["n_chunks"] += 1
                    rec["n_rows"] += len(ev["rows"])
                elif ev["event"] == "done":
                    rec["status"] = ev["result"]["status"]
                    rec["latency_ms"] = (now - t_sched) * 1e3
                elif ev["event"] == "error":
                    rec["status"] = "error"
                    rec["error"] = f"{ev['code']}: {ev['message']}"
                    rec["latency_ms"] = (now - t_sched) * 1e3
        except (ServerError, OSError, Exception) as e:  # noqa: BLE001
            rec["status"] = "error"
            rec["error"] = repr(e)
            rec["latency_ms"] = (time.perf_counter() - t0
                                 - t_sched) * 1e3
        records[i] = rec

    threads = [threading.Thread(target=worker, args=(i,), daemon=True)
               for i in range(n)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return _aggregate(records, wall, mix, rate=rate,
                      offered_qps=(n / arrivals[-1]
                                   if rate > 0 and arrivals[-1] > 0
                                   else None))


def run_burst(host: str, port: int, queries: list, *,
              n_threads: int = 8, tenant_mix: list[str] | None = None,
              limit: int | None = None) -> dict:
    """Closed-loop capacity probe (``--rate 0``): ``n_threads`` workers,
    each with its own connection, issue requests back-to-back until
    ``len(queries)`` complete. Latency is send -> terminal event (no
    scheduled arrival — the closed loop has none). This is the wire
    side of the ``server_overhead`` ratio: peak goodput through HTTP +
    NDJSON + admission vs the engine's own in-process batch."""
    from repro.server.client import ServeClient, ServerError

    mix = tenant_mix or TENANT_MIX
    n = len(queries)
    records: list[dict] = [None] * n
    options = {} if limit is None else {"limit": limit}
    t0 = time.perf_counter()

    def worker(idxs: list[int]) -> None:
        client = ServeClient(host, port)
        for i in idxs:
            tenant = mix[i % len(mix)]
            t_send = time.perf_counter() - t0
            rec = {"i": i, "tenant": tenant, "t_sched_s": float(t_send),
                   "send_delay_ms": 0.0, "n_chunks": 0, "n_rows": 0,
                   "ttfe_ms": None, "status": None, "error": None}
            try:
                for ev in client.stream(queries[i], tenant=tenant,
                                        options=options, request_id=i):
                    now = time.perf_counter() - t0
                    if ev["event"] == "chunk" and ev["rows"]:
                        if rec["n_chunks"] == 0:
                            rec["ttfe_ms"] = (now - t_send) * 1e3
                        rec["n_chunks"] += 1
                        rec["n_rows"] += len(ev["rows"])
                    elif ev["event"] == "done":
                        rec["status"] = ev["result"]["status"]
                        rec["latency_ms"] = (now - t_send) * 1e3
                    elif ev["event"] == "error":
                        rec["status"] = "error"
                        rec["error"] = f"{ev['code']}: {ev['message']}"
                        rec["latency_ms"] = (now - t_send) * 1e3
            except (ServerError, OSError, Exception) as e:  # noqa: BLE001
                rec["status"] = "error"
                rec["error"] = repr(e)
                rec["latency_ms"] = (time.perf_counter() - t0
                                     - t_send) * 1e3
            records[i] = rec

    k = max(1, min(n_threads, n))
    shards = [list(range(w, n, k)) for w in range(k)]
    threads = [threading.Thread(target=worker, args=(s,), daemon=True)
               for s in shards if s]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    return _aggregate(records, wall, mix, rate=0.0, offered_qps=None)


def _aggregate(records: list[dict], wall: float, mix: list[str], *,
               rate: float, offered_qps: float | None) -> dict:
    statuses: dict[str, int] = {}
    for r in records:
        statuses[r["status"]] = statuses.get(r["status"], 0) + 1
    good = [r for r in records if r["status"] in ("ok", "limit")]
    lat = np.asarray([r["latency_ms"] for r in records
                      if r.get("latency_ms") is not None])
    ttfe = np.asarray([r["ttfe_ms"] for r in records
                       if r["ttfe_ms"] is not None])

    per_tenant: dict[str, dict] = {}
    for name in sorted(set(mix)):
        rs = [r for r in records if r["tenant"] == name]
        g = [r for r in rs if r["status"] in ("ok", "limit")]
        tl = np.asarray([r["latency_ms"] for r in rs
                         if r.get("latency_ms") is not None])
        per_tenant[name] = {
            "n": len(rs), "completed": len(g),
            "goodput_qps": len(g) / wall if wall > 0 else 0.0,
            "shed": sum(r["status"] == "shed" for r in rs),
            "errors": sum(r["status"] == "error" for r in rs),
            "p50_ms": float(np.percentile(tl, 50)) if len(tl) else None,
            "p99_ms": float(np.percentile(tl, 99)) if len(tl) else None,
            "weight": TENANTS.get(name, {}).get("weight", 1.0),
        }
    # Jain's fairness over per-weight goodput: 1.0 = every tenant got
    # exactly its weighted share of the served throughput
    shares = np.asarray([t["goodput_qps"] / t["weight"]
                         for t in per_tenant.values()])
    fairness = (float(shares.sum() ** 2 / (len(shares)
                                           * (shares ** 2).sum()))
                if len(shares) and shares.sum() > 0 else None)

    return {
        "open_loop": rate > 0,
        "target_rate_qps": float(rate),
        "n_requests": len(records),
        "wall_time_s": wall,
        "offered_qps": offered_qps,
        "goodput_qps": len(good) / wall if wall > 0 else 0.0,
        "statuses": statuses,
        "shed": statuses.get("shed", 0),
        "errors": statuses.get("error", 0),
        "p50_ms": float(np.percentile(lat, 50)) if len(lat) else None,
        "p99_ms": float(np.percentile(lat, 99)) if len(lat) else None,
        "ttfe_p50_ms": (float(np.percentile(ttfe, 50))
                        if len(ttfe) else None),
        "ttfe_p99_ms": (float(np.percentile(ttfe, 99))
                        if len(ttfe) else None),
        "total_rows": int(sum(r["n_rows"] for r in records)),
        "per_tenant": per_tenant,
        "fairness_jain": fairness,
        "queries": records,
    }


# ----------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default=None,
                    help="target a running server (with --port)")
    ap.add_argument("--port", type=int, default=None)
    ap.add_argument("--launch", action="store_true",
                    help="spawn + tear down the server process here")
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale CI run; never writes "
                         "BENCH_serving.json")
    ap.add_argument("--rate", type=float, default=None,
                    help="open-loop arrival rate (req/s); 0 = burst; "
                         "default: smoke 8.0, full ladder")
    ap.add_argument("--n-requests", type=int, default=None)
    ap.add_argument("--repeats", type=int, default=1,
                    help="reruns per rate, keeping the best-goodput "
                         "row (wave-level noise dominates the tiny "
                         "burst walls)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.launch == (args.host is not None):
        ap.error("pass exactly one of --launch or --host/--port")
    if args.host is not None and args.port is None:
        ap.error("--host requires --port")

    if args.smoke:
        n_req = args.n_requests or 12
        graph_v, graph_e, qsize = 128, 128, 4
        server_args = SMOKE_GRAPH + SMOKE_ENGINE + [
            "--warmup-queries", "4", "--quiet"]
        rates = [args.rate if args.rate is not None else 8.0]
    else:
        n_req = args.n_requests or 64
        graph_v, graph_e, qsize = 512, 512, 6
        server_args = FULL_GRAPH + ["--limit", "1000",
                                    "--time-budget-s", "10", "--quiet"]
        rates = ([args.rate] if args.rate is not None
                 else [15.0, 40.0, 100.0])

    burst = len(rates) == 1 and rates[0] <= 0
    if burst:
        # mirror the server's in-process warmup baseline batch exactly
        # (same generator seed/size as MatchServer.warmup) so the
        # wire-vs-in-process overhead ratio compares identical work
        queries = _build_queries(graph_v, graph_e, 4, 8, seed=1)
    else:
        queries = _build_queries(graph_v, graph_e, qsize,
                                 min(n_req, 32))

    proc = None
    info = {}
    try:
        if args.launch:
            proc, info = launch_server(server_args)
            host, port = info["host"], info["port"]
        else:
            host, port = args.host, args.port

        runs = []
        for rate in rates:
            reqs = [queries[i % len(queries)] for i in range(n_req)]
            row = None
            for rep in range(max(args.repeats, 1)):
                cand = run_load(host, port, reqs, rate=rate,
                                seed=args.seed + rep)
                if row is None \
                        or cand["goodput_qps"] > row["goodput_qps"]:
                    row = cand
            runs.append(row)
            ttfe = row["ttfe_p50_ms"]
            print(f"# rate={rate:g}: goodput="
                  f"{row['goodput_qps']:.1f} qps "
                  f"p50={row['p50_ms']:.0f}ms "
                  f"ttfe_p50={ttfe if ttfe is None else round(ttfe)}ms "
                  f"shed={row['shed']} errors={row['errors']} "
                  f"fairness={row['fairness_jain']}", file=sys.stderr)

        from repro.server.client import ServeClient
        c = ServeClient(host, port)
        slo = c.slo()
        payload = runs[0] if len(runs) == 1 else {
            "open_loop": True,
            "rates": runs,
            # headline: the highest-goodput rung of the ladder
            "headline": max(runs, key=lambda r: r["goodput_qps"]),
        }
        payload["server"] = {"host": host, "port": port,
                             "launched": bool(args.launch)}
        payload["server_slo"] = slo
        if burst and info.get("baseline_qps"):
            # wire tax: burst goodput over the server's own in-process
            # baseline (same engine instance, same queries) — gated by
            # scripts/ab_gate.py's server_overhead leg
            payload["inprocess_qps"] = info["baseline_qps"]
            payload["server_overhead"] = (payload["goodput_qps"]
                                          / info["baseline_qps"])
            print(f"# server_overhead="
                  f"{payload['server_overhead']:.3f} "
                  f"(wire {payload['goodput_qps']:.1f} / in-process "
                  f"{payload['inprocess_qps']:.1f} qps)",
                  file=sys.stderr)
    finally:
        if proc is not None:
            code = stop_server(proc)
            if code not in (0, -signal.SIGTERM):
                print(f"# server exited with code {code}",
                      file=sys.stderr)

    if not args.smoke and _OUT.exists():
        bench = json.loads(_OUT.read_text())
        bench["serving_tier"] = payload
        _OUT.write_text(json.dumps(bench, indent=2) + "\n")
        print(f"# wrote serving_tier -> {_OUT}", file=sys.stderr)
    print(json.dumps(payload, indent=2))
    return 0


if __name__ == "__main__":
    sys.path.insert(0, str(ROOT / "src"))
    sys.exit(main())
