"""Wave-engine benchmarks: device-step latency, wave-size/learning
tradeoff, and kernel microbenchmarks (Pallas vs jnp reference)."""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.graph import pack_bitmap
from repro.core.vectorized import match_vectorized
from repro.data.graph_gen import trap_graph, yeast_like_graph, query_set
from repro.kernels.ops import bitmap_spmm_op, flash_attention_op, refine_bitmap_op


def _time_call(fn, *args, reps=5):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(csv_rows: list, budget_s: float = 90.0) -> None:
    t0 = time.time()
    # --- wave-size / learning-latency tradeoff ---------------------------
    q, g = trap_graph(n_b=80, n_c=80, n_good=2, tail_len=2, seed=0)
    for ws, kpr in ((16, 4), (64, 8), (256, 16)):
        r = match_vectorized(q, g, limit=None, wave_size=ws, kpr=kpr)
        csv_rows.append((f"engine_trap80_ws{ws}",
                         r.stats.wall_time_s * 1e6,
                         f"rows={r.stats.rows_created};"
                         f"waves={r.stats.waves};"
                         f"prunes={r.stats.deadend_prunes}"))
    # --- engine on matched-statistics workload ---------------------------
    data = yeast_like_graph(0)
    queries = query_set(data, 8, 3, seed=77)
    tot = 0.0
    rows = 0
    for qq in queries:
        r = match_vectorized(qq, data, limit=1000, wave_size=256, kpr=16)
        tot += r.stats.wall_time_s
        rows += r.stats.rows_created
    csv_rows.append(("engine_yeastlike_q8", tot * 1e6 / len(queries),
                     f"rows={rows}"))

    # --- kernel microbenchmarks (interpret mode vs jnp oracle) -----------
    rng = np.random.default_rng(0)
    v = 2048
    dense = rng.random((v, v)) < 0.01
    adj = jnp.asarray(pack_bitmap(dense))
    cand = jnp.asarray(pack_bitmap(rng.random((1, v)) < 0.5)[0])
    frontier = jnp.asarray(rng.integers(0, v, (256, 16)).astype(np.int32))
    active = jnp.asarray((rng.random(16) < 0.5).astype(np.int32))
    us = _time_call(lambda *a: refine_bitmap_op(*a, backend="jnp"),
                    adj, cand, frontier, active)
    csv_rows.append(("kernel_refine_jnp_v2048_f256", us, "backend=jnp"))
    if time.time() - t0 < budget_s:
        x = jnp.asarray(rng.standard_normal((v, 128)), jnp.float32)
        us = _time_call(lambda *a: bitmap_spmm_op(*a, backend="jnp"),
                        adj, x)
        csv_rows.append(("kernel_spmm_jnp_v2048_d128", us, "backend=jnp"))
        qkv = jnp.asarray(rng.standard_normal((1, 4, 512, 64)),
                          jnp.float32)
        us = _time_call(
            lambda a: flash_attention_op(a, a, a, backend="jnp"), qkv)
        csv_rows.append(("kernel_flashattn_jnp_s512", us, "backend=jnp"))
