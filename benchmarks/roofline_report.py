"""Aggregate the dry-run JSONs into the §Roofline table
(experiments/roofline.csv + CSV rows for the harness)."""
from __future__ import annotations

import json
import pathlib


def run(csv_rows: list, dryrun_dir: str = "experiments/dryrun") -> None:
    d = pathlib.Path(dryrun_dir)
    if not d.exists():
        csv_rows.append(("roofline_missing", 0.0,
                         "run repro.launch.dryrun first"))
        return
    recs = []
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") != "ok":
            csv_rows.append((f"roofline_{p.stem}", 0.0,
                             f"status={r.get('status')}"))
            continue
        recs.append(r)
        dom = max(r["t_compute_s"], r["t_memory_s"], r["t_collective_s"])
        csv_rows.append((
            f"roofline_{r['arch']}__{r['shape']}__{r['mesh']}",
            dom * 1e6,
            f"bottleneck={r['bottleneck']};"
            f"tc={r['t_compute_s']:.3e};tm={r['t_memory_s']:.3e};"
            f"tx={r['t_collective_s']:.3e};"
            f"useful={r['useful_flops_frac'] if r['useful_flops_frac'] else ''}"))
    lines = ["arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,"
             "bottleneck,useful_flops_frac,mem_temp_bytes"]
    for r in recs:
        mem = ""
        if r.get("memory_analysis"):
            import re
            m = re.search(r"temp_size_in_bytes=(\d+)",
                          r["memory_analysis"])
            mem = m.group(1) if m else ""
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},"
            f"{r['t_compute_s']:.6e},{r['t_memory_s']:.6e},"
            f"{r['t_collective_s']:.6e},{r['bottleneck']},"
            f"{r['useful_flops_frac'] or ''},{mem}")
    pathlib.Path("experiments/roofline.csv").write_text(
        "\n".join(lines) + "\n")
