"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,fig4,engine,roofline]

Prints ``name,us_per_call,derived`` CSV rows and writes
experiments/bench_results.csv.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="fig4,fig3,engine,serving,roofline")
    ap.add_argument("--budget-s", type=float, default=90.0)
    args = ap.parse_args()
    which = set(args.only.split(","))
    rows: list[tuple] = []
    t0 = time.time()

    if "fig4" in which:
        from . import paper_fig4_recursions
        paper_fig4_recursions.run(rows, budget_s=args.budget_s)
    if "fig3" in which:
        from . import paper_fig3_query_time
        paper_fig3_query_time.run(rows, budget_s=args.budget_s)
    if "engine" in which:
        from . import engine_bench
        engine_bench.run(rows, budget_s=args.budget_s)
    if "serving" in which:
        from . import serving_bench
        serving_bench.run(rows, budget_s=args.budget_s)
    if "roofline" in which:
        from . import roofline_report
        roofline_report.run(rows)

    print("name,us_per_call,derived")
    out_lines = ["name,us_per_call,derived"]
    for name, us, derived in rows:
        line = f"{name},{us:.2f},{derived}"
        print(line)
        out_lines.append(line)
    out = pathlib.Path("experiments")
    out.mkdir(exist_ok=True)
    (out / "bench_results.csv").write_text("\n".join(out_lines) + "\n")
    print(f"# total {time.time() - t0:.1f}s, {len(rows)} rows "
          f"-> experiments/bench_results.csv", file=sys.stderr)


if __name__ == "__main__":
    main()
