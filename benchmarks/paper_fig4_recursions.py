"""Paper Fig. 4 analogue: recursion counts, dead-end pruning vs 'No
pruning', per query size — the paper's core mechanism measurement.

Paper claim: pruning reduces recursions by orders of magnitude as query
size grows (6.7e10 -> 2.4e7 at 18 vertices on yeast). We reproduce the
*relative* effect on matched-statistics synthetic graphs plus the
trap-instance family that isolates the mechanism (Theta(n^2) -> Theta(n)).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.backtrack import backtrack_deadend
from repro.data.graph_gen import (human_like_graph, query_set, trap_graph,
                                  yeast_like_graph)


def run(csv_rows: list, budget_s: float = 60.0) -> None:
    t_start = time.time()
    # --- trap family: the paper's Fig. 1/2 mechanism, scaled -------------
    for n in (50, 100, 200):
        q, g = trap_graph(n_b=n, n_c=n, n_good=2, tail_len=2, seed=0)
        a = backtrack_deadend(q, g, limit=None)
        b = backtrack_deadend(q, g, limit=None, use_pruning=False)
        csv_rows.append((f"fig4_trap_n{n}_pruned",
                         a.stats.wall_time_s * 1e6 / max(a.stats.found, 1),
                         f"recursions={a.stats.recursions}"))
        csv_rows.append((f"fig4_trap_n{n}_nopruning",
                         b.stats.wall_time_s * 1e6 / max(b.stats.found, 1),
                         f"recursions={b.stats.recursions};"
                         f"ratio={b.stats.recursions/a.stats.recursions:.1f}"))
    # --- matched-statistics graphs, random-walk query sets ---------------
    for name, graph in (("yeastlike", yeast_like_graph(0)),
                        ("humanlike", human_like_graph(0))):
        for nq in (8, 12, 16):
            if time.time() - t_start > budget_s:
                return
            queries = query_set(graph, nq, 5, seed=nq)
            rec_p = rec_u = 0
            t_p = t_u = 0.0
            for q in queries:
                a = backtrack_deadend(q, graph, limit=1000,
                                      max_recursions=300_000)
                b = backtrack_deadend(q, graph, limit=1000,
                                      use_pruning=False,
                                      max_recursions=300_000)
                rec_p += a.stats.recursions
                rec_u += b.stats.recursions
                t_p += a.stats.wall_time_s
                t_u += b.stats.wall_time_s
            csv_rows.append((f"fig4_{name}_q{nq}_pruned",
                             t_p * 1e6 / len(queries),
                             f"recursions={rec_p}"))
            csv_rows.append((f"fig4_{name}_q{nq}_nopruning",
                             t_u * 1e6 / len(queries),
                             f"recursions={rec_u};"
                             f"ratio={rec_u/max(rec_p,1):.2f}"))
